"""Real-engine microbenchmarks on CPU with a reduced MoE: wall-clock per
call for the serving primitives (decode step, n-gram drafter, rejection
sampler, Cascade manager). These verify the paper's claim that the
manager/telemetry overhead is negligible relative to an MoE iteration.

`--batch-sweep` runs the continuous-batching engine on the deterministic
model clock for B in {1,2,4,8} and reports, per batch size: batch-union
unique experts per iteration, tokens/s, and mean per-request utility — the
paper's Fig. 2 expert-union inflation, now compounding across requests
(speculation utility degrades as the batch grows because the union term is
shared). The B=1 row is cross-checked against the legacy single-request
engine (must agree within 1%).

`--planner-sweep` compares the batch-level speculation planner
(policy="joint", docs/planner.md) against independent per-request control
over the same grid, with two gates: joint tokens/s must be >= independent
at B=8 (where the expert union saturates and uncoordinated trials tax the
shared pass), and at B=1 the two policies must agree *exactly* (the
planner bypass must be invisible, bit for bit).

Every sweep is one `SWEEPS` table entry: flag registration, dispatch, and
the shared engine/scheduler/model-clock boilerplate (`_run_engine`) and
gate evaluation (`_gate`) live in one place, so a new sweep (most
recently `--offload-sweep`, docs/offload.md) is a function plus a table
row, not a seventh copy of the entrypoint."""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import CascadeController
from repro.core import cost_model as cm
from repro.core.utility import IterationRecord
from repro.models import transformer as T
from repro.serving import (BatchedEngine, ContinuousBatchingScheduler,
                           NGramDrafter, Request, Scheduler, ServingEngine)
from repro.serving.sampler import rejection_sample

from .common import emit, save_json


def _bench(fn, n=50, warmup=3):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6  # us


def main(fast: bool = False):
    cfg = get_config("mixtral-8x7b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    cache = T.init_cache(cfg, 1, 512)
    toks = jnp.asarray(np.arange(64)[None, :] % cfg.vocab_size, jnp.int32)
    _, cache, _ = jax.jit(lambda p, t, c: T.prefill(cfg, p, t, c))(
        params, toks, cache)
    step = jax.jit(lambda p, c, t: T.decode_step(cfg, p, c, t))
    tok4 = toks[:, :4]

    us = _bench(lambda: jax.block_until_ready(step(params, cache, tok4)[0]))
    emit("serving_micro/decode_step_T4_reduced_moe", us, "jit;cpu")

    drafter = NGramDrafter()
    hist = list(np.random.default_rng(0).integers(0, 64, 512))
    us = _bench(lambda: drafter.propose(hist, 4), n=200)
    emit("serving_micro/ngram_propose", us, "py;hist=512")

    rng = np.random.default_rng(0)
    p = rng.dirichlet(np.ones(256), size=5).astype(np.float64)
    us = _bench(lambda: rejection_sample(rng, p, [1, 2, 3, 4]), n=500)
    emit("serving_micro/rejection_sample_K4", us, "py;V=256")

    ctl = CascadeController()
    rec = IterationRecord(k=3, tokens=2, t_iter=1e-3)

    def tick():
        ctl.next_k()
        ctl.manager.observe(rec)
    us = _bench(tick, n=2000)
    emit("serving_micro/cascade_manager_tick", us,
         "py;paper-claims-negligible")


# --------------------------------------------------------------------- #
# Shared sweep runner: engine/scheduler boilerplate and gate evaluation
# --------------------------------------------------------------------- #

def _run_engine(cfg, params, reqs, *, controller=None, **engine_kw):
    """One continuous-batching run on the deterministic model clock —
    the shared body of every `SWEEPS` entry. Returns (engine, scheduler)
    after the scheduler has drained `reqs`."""
    engine_kw.setdefault("max_len", 512)
    eng = BatchedEngine(cfg, params, lambda: NGramDrafter(),
                        temperature=0.0, clock="model", seed=0, **engine_kw)
    sched = ContinuousBatchingScheduler(
        eng, controller_factory=controller or (lambda: CascadeController()))
    sched.run(reqs)
    return eng, sched


def _gate(ok: bool, msg: str):
    """A sweep gate: falsy -> the run exits nonzero with `msg` (CI smoke
    and the committed artifacts share the same gates)."""
    if not ok:
        raise SystemExit(msg)


# --------------------------------------------------------------------- #
# Continuous-batching sweep (model clock)
# --------------------------------------------------------------------- #

def _sweep_requests(cfg, n_requests: int, max_new: int):
    """Draftable task-tagged prompts (periodic patterns of varying period,
    so requests disagree on routing but n-gram drafting gets traction)."""
    rng = np.random.default_rng(11)
    reqs = []
    for i in range(n_requests):
        period = 4 + 2 * (i % 4)
        pat = list(rng.integers(3, cfg.vocab_size, period))
        reqs.append(Request(request_id=f"r{i}", prompt=pat * (32 // period),
                            max_new=max_new, task=f"p{period}"))
    return reqs


def batch_sweep(fast: bool = False, batches=(1, 2, 4, 8)):
    cfg = get_config("mixtral-8x7b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    n_requests = max(batches)
    max_new = 16 if fast else 32

    # legacy single-request engine: the pre-refactor reference for B=1
    leg_eng = ServingEngine(cfg, params, NGramDrafter(), max_len=512,
                            temperature=0.0, clock="model", seed=0)
    leg = Scheduler(leg_eng,
                    controller_factory=lambda: CascadeController())
    leg.run(_sweep_requests(cfg, n_requests, max_new))
    leg_tps = leg.tokens_per_second()
    emit("serving_micro/legacy_B1_tokens_per_s", leg_tps, "model-clock")

    rows = []
    for b in batches:
        # pinned to the uncoordinated per-request baseline: this sweep IS
        # the measurement of what independent Cascade control does to
        # utility as the union saturates (the batch planner's motivation —
        # --planner-sweep measures the coordinated engine against it)
        eng, sched = _run_engine(cfg, params,
                                 _sweep_requests(cfg, n_requests, max_new),
                                 max_batch=b, policy="independent")
        tel = eng.telemetry
        row = {
            "B": b,
            "union_experts_per_iter": tel.mean_union_experts,
            "tokens_per_s": sched.tokens_per_second(),
            "mean_request_utility": sched.mean_request_utility(),
            "mean_occupancy": tel.mean_occupancy,
            "padding_frac": tel.mean_padding_frac,
            "steps": len(tel.steps),
        }
        rows.append(row)
        emit(f"serving_micro/batch_B{b}_union_experts",
             row["union_experts_per_iter"], "per-iter;mean-layers")
        emit(f"serving_micro/batch_B{b}_tokens_per_s",
             row["tokens_per_s"], f"occ={row['mean_occupancy']:.2f}")
        emit(f"serving_micro/batch_B{b}_mean_utility",
             row["mean_request_utility"],
             f"pad={row['padding_frac']:.3f}")

    b1_rows = [r for r in rows if r["B"] == 1]
    if not b1_rows:
        raise ValueError("batch sweep needs B=1 for the legacy cross-check")
    b1_tps = b1_rows[0]["tokens_per_s"]
    drift = abs(b1_tps - leg_tps) / leg_tps if leg_tps else 0.0
    emit("serving_micro/batch_B1_vs_legacy_drift", drift,
         "must-be<0.01")
    save_json("serving_micro_batch_sweep",
              {"legacy_B1_tokens_per_s": leg_tps, "rows": rows,
               "b1_drift": drift})
    _gate(drift < 0.01,
          f"B=1 tokens/s drifted {drift:.2%} from the legacy engine")
    return rows


# --------------------------------------------------------------------- #
# Batch-planner sweep (model clock): joint vs independent K allocation
# --------------------------------------------------------------------- #

# On full-size TPU-v5e numbers the reduced CPU model's whole pass collapses
# into the fixed per-step overhead and every allocation policy ties. This
# point scales the hardware down so the reduced model's shared pass sits
# where full-size large-batch serving does: memory-bound at the
# no-speculation allocation, crossing the roofline once B=8 draft spans
# stack up (~11 in-flight tokens for the reduced Mixtral) — the regime
# where one request's aggressive K costs every request real time and joint
# planning has teeth. A regime choice, not a physical device.
def _planner_hw():
    from repro.core import Hardware
    return Hardware("tpu-v5e-flops-scaled", hbm_bw=1e9, peak_flops=6e9)


def planner_sweep(fast: bool = False, batches=(1, 2, 4, 8)):
    """Joint-vs-independent allocation over B in {1,2,4,8} on the model
    clock (same draftable workload as `batch_sweep`, PLANNER_SWEEP_HW
    regime). Reports tokens/s, mean per-request utility, grant ratio,
    preemptions, staggered (held) TEST trials, and the planner's
    predicted-vs-measured step-time error. Gates (committed artifact +
    CI smoke): joint >= independent tokens/s at max(batches); B=1 drift
    between the policies exactly 0."""
    cfg = get_config("mixtral-8x7b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    hw = _planner_hw()
    n_requests = max(batches)
    max_new = 16 if fast else 32

    rows = []
    tps = {}
    for policy in ("independent", "joint"):
        for b in batches:
            eng, sched = _run_engine(
                cfg, params, _sweep_requests(cfg, n_requests, max_new),
                max_batch=b, hw=hw, policy=policy)
            tel = eng.telemetry
            stats = sched.planner_stats()
            row = {
                "policy": policy,
                "B": b,
                "tokens_per_s": sched.tokens_per_second(),
                "mean_request_utility": sched.mean_request_utility(),
                "union_experts_per_iter": tel.mean_union_experts,
                "grant_ratio": stats["grant_ratio"],
                "preemptions": stats["preemptions"],
                "held_tests": stats["held_tests"],
                "plan_time_error": stats["plan_time_error"],
                "steps": len(tel.steps),
            }
            rows.append(row)
            tps[(policy, b)] = row["tokens_per_s"]
            emit(f"serving_micro/planner_{policy}_B{b}_tokens_per_s",
                 row["tokens_per_s"],
                 f"grant={row['grant_ratio']:.3f};"
                 f"held={row['held_tests']};err={row['plan_time_error']:.3f}")

    deep = max(batches)
    gain = (tps[("joint", deep)] / tps[("independent", deep)]
            if tps[("independent", deep)] else 0.0)
    drift = abs(tps[("joint", 1)] - tps[("independent", 1)])
    emit(f"serving_micro/planner_B{deep}_joint_over_independent", gain,
         "must-be>=1")
    emit("serving_micro/planner_B1_policy_drift", drift, "must-be-exactly-0")
    save_json("serving_micro_planner_sweep",
              {"hw": {"name": hw.name, "hbm_bw": hw.hbm_bw,
                      "peak_flops": hw.peak_flops},
               "max_new": max_new, "rows": rows,
               "deep_B": deep, "joint_over_independent": gain,
               "b1_policy_drift": drift})
    _gate(drift == 0.0,
          f"B=1 joint policy drifted {drift!r} tokens/s from the "
          "independent controller path (must be exactly 0)")
    _gate(gain >= 1.0,
          f"joint allocation lost to independent control at B={deep}: "
          f"{tps[('joint', deep)]:.2f} vs "
          f"{tps[('independent', deep)]:.2f} tokens/s (x{gain:.4f})")
    return rows


# --------------------------------------------------------------------- #
# SLO sweep (model clock): mixed-tier traffic under TPOT bounds
# --------------------------------------------------------------------- #

def _slo_requests(cfg, n_requests: int, max_new: int, bound,
                  neutral: bool = False):
    """The planner-sweep workload with SLOs attached. `neutral`: every
    request carries an *unbounded throughput-tier* RequestSLO — the
    constraint pipeline fully engaged but provably inert (the no-SLO
    drift gate's subject). Otherwise odd requests are latency-tier
    carrying `bound` (None = unbounded latency marker: tier weighting
    active, victim protection not), even requests plain throughput."""
    from repro.core import RequestSLO
    reqs = _sweep_requests(cfg, n_requests, max_new)
    for i, r in enumerate(reqs):
        if neutral:
            r.slo = RequestSLO()
        elif i % 2 == 1:
            r.slo = RequestSLO.latency(tpot=bound)
    return reqs


def slo_sweep(fast: bool = False, batches=(4, 8)):
    """Mixed-tier SLO sweep on the planner-sweep crossover regime
    (docs/slo.md). Per batch size, four runs over the same workload/seed:

      * zero   — speculation disabled (StaticK 0): measures the latency
        rows' no-speculation experienced TPOT, the feasibility floor the
        bound is calibrated from;
      * free   — the unconstrained joint planner, no SLO anywhere (the
        PR-4 path the no-SLO drift gate pins);
      * unbounded — every request carries an *unbounded* RequestSLO: the
        constraint pipeline engaged but inert;
      * mixed  — latency rows bounded at the calibrated TPOT
        (between the zero floor and what `free` inflicted on them).

    Gates (committed artifact + CI smoke):
      * no-SLO drift: `unbounded` tokens/s == `free` EXACTLY, per B (the
        pipeline must be invisible without bounds);
      * at B=max: every latency-tier request meets its bound (p95 and max
        reported), with the planner actually denying grants
        (slo_denied > 0 — the gate must not pass vacuously);
      * at B=max: throughput-tier tokens/s in `mixed` >= 0.95x the same
        rows' tokens/s under the unconstrained planner — victim
        protection must not collapse batch throughput."""
    from repro.core import StaticKController
    cfg = get_config("mixtral-8x7b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    hw = _planner_hw()
    if fast:
        batches = tuple(b for b in batches if b == max(batches))
    n_requests = max(batches)
    max_new = 16 if fast else 32

    def run(b, bound, zero=False, neutral=False):
        fac = (lambda: StaticKController(0)) if zero else None
        eng, sched = _run_engine(
            cfg, params,
            _slo_requests(cfg, n_requests, max_new, bound, neutral=neutral),
            controller=fac, max_batch=b, hw=hw)
        res = sched.results
        t_steps = sum(s.t_total for s in eng.telemetry.steps)
        tiers = {"latency": [], "throughput": []}
        for r in res:
            tiers[r.telemetry.tier].append(r.telemetry)
        out = {"tokens_per_s": sched.tokens_per_second(),
               "t_steps": t_steps, "stats": sched.planner_stats(),
               "tier_stats": sched.tier_stats(),
               "violations": sched.slo_violations()}
        for tier, tels in tiers.items():
            toks = sum(t.output_tokens for t in tels)
            out[f"{tier}_tokens_per_s"] = (toks / t_steps if t_steps
                                           else 0.0)
            tpots = [t.experienced_tpot for t in tels if t.output_tokens]
            out[f"{tier}_max_tpot"] = max(tpots) if tpots else 0.0
        return out

    rows = []
    drift_max = 0.0
    gates = {}
    for b in batches:
        zero = run(b, None, zero=True)
        # `free` carries UNBOUNDED latency markers: tier weighting active,
        # victim protection not — the reference `mixed` differs from only
        # in the bound. The no-SLO drift gate instead compares the bare
        # run (no SLO objects anywhere — the PR-4 construction) against
        # `neutral` (unbounded throughput-tier SLOs on every request: the
        # pipeline engaged but provably inert) — exactly 0 or the
        # refactor leaks into unbounded traffic.
        free = run(b, None)
        neutral = run(b, None, neutral=True)
        eng, sched = _run_engine(cfg, params,
                                 _sweep_requests(cfg, n_requests, max_new),
                                 max_batch=b, hw=hw)
        bare_res = sched.results
        bare_tps = sched.tokens_per_second()
        drift = abs(neutral["tokens_per_s"] - bare_tps)
        drift_max = max(drift_max, drift)
        # the retention gate's denominator: the SAME (even-parity) rows'
        # tokens/s under the TRULY unconstrained planner — no SLO objects
        # anywhere, so no tier weighting either (`free` is tier-weighted
        # even unbounded, which would flatter the ratio)
        bare_t_steps = sum(s.t_total for s in eng.telemetry.steps)
        bare_thr_tps = (sum(r.telemetry.output_tokens
                            for i, r in enumerate(bare_res) if i % 2 == 0)
                        / bare_t_steps if bare_t_steps else 0.0)

        # calibrate the bound: between the zero-spec floor and what the
        # free-running planner inflicted on the latency rows, but never
        # tighter than 2% above the floor — the planner denies on its
        # *predicted* pass time, and a band narrower than the analytic
        # union's prediction error would demand clairvoyance, not control
        floor = zero["latency_max_tpot"]
        worst = free["latency_max_tpot"]
        bound = max(0.5 * (floor + worst), 1.02 * floor)
        mixed = run(b, bound)
        row = {
            "B": b, "bound": bound,
            "zero_latency_tpot": floor,
            "free_latency_tpot": worst,
            "mixed_latency_tpot": mixed["latency_max_tpot"],
            "mixed_latency_p95": mixed["tier_stats"]
            .get("latency", {}).get("p95_tpot", 0.0),
            "free_tokens_per_s": free["tokens_per_s"],
            "bare_tokens_per_s": bare_tps,
            "mixed_tokens_per_s": mixed["tokens_per_s"],
            "free_throughput_tokens_per_s": free["throughput_tokens_per_s"],
            "bare_throughput_tokens_per_s": bare_thr_tps,
            "mixed_throughput_tokens_per_s":
                mixed["throughput_tokens_per_s"],
            "slo_denied": mixed["stats"]["slo_denied"],
            "violations": mixed["violations"],
            "no_slo_drift": drift,
        }
        rows.append(row)
        emit(f"serving_micro/slo_B{b}_mixed_latency_tpot",
             row["mixed_latency_tpot"],
             f"bound={bound:.5f};denied={row['slo_denied']}")
        emit(f"serving_micro/slo_B{b}_throughput_retention",
             (row["mixed_throughput_tokens_per_s"] / bare_thr_tps
              if bare_thr_tps else 0.0),
             "mixed/bare-unconstrained")
        if b == max(batches):
            gates = row

    deep = max(batches)
    retention = (gates["mixed_throughput_tokens_per_s"]
                 / gates["bare_throughput_tokens_per_s"]
                 if gates["bare_throughput_tokens_per_s"] else 0.0)
    emit("serving_micro/slo_no_slo_drift", drift_max, "must-be-exactly-0")
    emit(f"serving_micro/slo_B{deep}_latency_bound_met",
         float(gates["violations"] == 0), "must-be-1")
    emit(f"serving_micro/slo_B{deep}_throughput_retention", retention,
         "must-be>=0.95")
    save_json("serving_micro_slo_sweep",
              {"hw": {"name": hw.name, "hbm_bw": hw.hbm_bw,
                      "peak_flops": hw.peak_flops},
               "max_new": max_new, "rows": rows, "deep_B": deep,
               "no_slo_drift": drift_max,
               "throughput_retention": retention})
    _gate(drift_max == 0.0,
          f"no-SLO tokens/s drifted {drift_max!r} from the bare planner "
          "path (must be exactly 0: the constraint pipeline must be "
          "invisible without bounds)")
    for row in rows:
        _gate(row["violations"] == 0,
              f"latency-tier TPOT bound violated at B={row['B']}: max "
              f"{row['mixed_latency_tpot']:.5f} vs bound "
              f"{row['bound']:.5f}")
    _gate(gates["slo_denied"] > 0,
          f"the bound never bound: planner denied 0 grants at B={deep} "
          "(the latency gate would be vacuous)")
    _gate(retention >= 0.95,
          f"throughput-tier tokens/s dropped to {retention:.3f}x the "
          f"unconstrained planner at B={deep} (must be >= 0.95)")
    return rows


# --------------------------------------------------------------------- #
# EP-shard sweep (model clock): shards x placement skew x B,
# shard-aware vs global-union planning on a sharded deployment
# --------------------------------------------------------------------- #

def _ep_model():
    """The reduced Mixtral widened back to 8 experts (a 4-expert reduction
    cannot express a skewed 4-shard placement — every shard would hold one
    expert — and 8 is the real Mixtral's count), trained ~200 steps on the
    periodic-copy task so greedy generations are genuinely n-gram-draftable
    (the conftest `trained_tiny_moe` recipe — real acceptance, real
    routing). Untrained reduced models emit non-repeating pseudo-random
    streams the drafter never matches, which would reduce every allocation
    policy to a tie of zero-yield grants."""
    import dataclasses
    from repro.training import make_train_step
    from repro.training.optimizer import adamw
    cfg = dataclasses.replace(get_config("mixtral-8x7b").reduced(),
                              num_experts=8, vocab_size=128, num_layers=2)
    init_state, step = make_train_step(cfg, optimizer=adamw(3e-3))
    state = init_state(jax.random.PRNGKey(1))
    step = jax.jit(step)
    rng = np.random.default_rng(3)

    def copy_batch(bs=16, period=32, seq=96):
        p = rng.integers(3, cfg.vocab_size, (bs, period))
        reps = seq // period + 2
        full = np.concatenate([np.ones((bs, 1), int)] + [p] * reps,
                              axis=1)[:, :seq + 1]
        mask = np.zeros((seq,), np.float32)
        mask[period:] = 1.0
        return {"tokens": jnp.asarray(full[:, :seq].astype(np.int32)),
                "labels": jnp.asarray(full[:, 1:seq + 1].astype(np.int32)),
                "mask": jnp.broadcast_to(jnp.asarray(mask), (bs, seq))}

    for _ in range(200):
        state, m = step(state, copy_batch())
    emit("serving_micro/ep_model_train_ce", float(m["ce"]), "200-steps")
    return cfg, state[0]


def _ep_hw():
    """Regime choice, not a physical device (cf. `_planner_hw`): bandwidth
    scaled so the trained reduced model's shared pass is memory-bound at
    the no-speculation allocation, with the compute roofline close enough
    that the global-union model's *under-counted* expert bytes place the
    crossover before the granted allocations while the true max-over-shards
    bytes keep the pass memory-bound — exactly the window where balanced
    accounting denies speculation a sharded deployment could afford."""
    from repro.core import Hardware
    return Hardware("tpu-v5e-ep-scaled", hbm_bw=1e9, peak_flops=1e10,
                    ici_bw=5e8)


def _ep_controller():
    """Fast-converging Cascade config for the trained-model sweeps:
    synchronized joins at B=8 would otherwise stretch the trial phases
    past the request lifetimes (the sweeps measure steady-state
    allocation, not FSM exploration)."""
    from repro.core import CascadeConfig
    return CascadeController(CascadeConfig(
        trial_len=2, max_trials=2, baseline_iters=2, set_len=64))


def _ep_requests(cfg, n_requests: int, max_new: int):
    """Draftable periodic prompts over the trained vocab (the copy task the
    model learned), varying period so requests route differently."""
    rng = np.random.default_rng(11)
    out = []
    for i in range(n_requests):
        period = 4 + 2 * (i % 4)
        pat = [int(x) for x in rng.integers(3, cfg.vocab_size, period)]
        out.append(Request(request_id=f"r{i}", prompt=pat * (32 // period),
                           max_new=max_new, task=f"p{period}"))
    return out


def ep_sweep(fast: bool = False, shards=(1, 2, 4),
             skews=("uniform", "zipf"), batches=(1, 4, 8)):
    """EP-sharded serving grid on the deterministic model clock
    (docs/expert_parallel.md). For each shard count and placement skew
    (`uniform` = contiguous equal blocks, `zipf` = zipf(2)-sized blocks —
    co-located popular experts concentrating the routed load on shard 0),
    the continuous-batching engine runs with measured per-shard activation
    accounting and either the shard-aware planner (max-over-shards
    pricing) or the global-union comparator (`shard_aware=False`: the
    union spread evenly over shards — the model that misprices the gating
    shard). Controllers use a fast-converging Cascade config with planner
    staggering off: synchronized joins at B=8 would otherwise stretch the
    trial phases past the request lifetimes and leave the water-filling
    nothing but pinned probes to allocate — the sweep measures
    steady-state allocation, not FSM exploration.

    `--fast` shrinks the grid to the gated corners (shards {1, max},
    B {1, max}), never the regime — the gates must mean the same thing in
    CI as in the committed artifact.

    Gates (committed artifact + CI smoke):
      * shards=1 tokens/s must equal the placement-free engine *exactly*
        (the sharded stack degrades bit-for-bit, per-batch-size);
      * the shard-aware planner must not lose to the global-union planner
        on the skewed placement at the deepest point (shards=4, zipf,
        B=max)."""
    from repro.core import (BatchSpecPlanner, ExpertPlacement,
                            PlannerConfig)
    cfg, params = _ep_model()
    hw = _ep_hw()
    if fast:
        shards = tuple(s for s in shards if s in (1, max(shards)))
        batches = tuple(b for b in batches if b in (1, max(batches)))
    n_requests = 2 * max(batches)
    max_new = 48
    controller = _ep_controller

    def run(placement, shard_aware, b):
        planner = BatchSpecPlanner(
            cfg, hw, config=PlannerConfig(policy="joint",
                                          shard_aware=shard_aware,
                                          stagger_tests=False),
            placement=placement)
        return _run_engine(cfg, params,
                           _ep_requests(cfg, n_requests, max_new),
                           controller=controller, max_batch=b, hw=hw,
                           placement=placement, planner=planner)

    rows = []
    tps = {}

    def record(planner_kind, skew, n_s, b, eng, sched):
        stats = sched.planner_stats()
        row = {
            "planner": planner_kind, "skew": skew, "shards": n_s, "B": b,
            "tokens_per_s": sched.tokens_per_second(),
            "mean_request_utility": sched.mean_request_utility(),
            "union_experts_per_iter": eng.telemetry.mean_union_experts,
            "grant_ratio": stats["grant_ratio"],
            "preemptions": stats["preemptions"],
            "mean_shard_imbalance": stats["mean_shard_imbalance"],
            "hot_shard_frac": stats["hot_shard_frac"],
            "plan_time_error": stats["plan_time_error"],
            "steps": len(eng.telemetry.steps),
        }
        rows.append(row)
        tps[(planner_kind, skew, n_s, b)] = row["tokens_per_s"]
        emit(f"serving_micro/ep_{planner_kind}_{skew}_s{n_s}_B{b}"
             f"_tokens_per_s", row["tokens_per_s"],
             f"imb={row['mean_shard_imbalance']:.2f};"
             f"grant={row['grant_ratio']:.3f};err={row['plan_time_error']:.3f}")
        return row

    e = cfg.num_experts
    for b in batches:
        eng, sched = run(None, True, b)
        record("none", "uniform", 0, b, eng, sched)       # shards=0: no EP
        eng, sched = run(ExpertPlacement.contiguous(e, 1), True, b)
        record("aware", "uniform", 1, b, eng, sched)
    for n_s in [s for s in shards if s > 1]:
        for skew in skews:
            pl = (ExpertPlacement.contiguous(e, n_s) if skew == "uniform"
                  else ExpertPlacement.zipf(e, n_s, alpha=2.0))
            for b in batches:
                for kind, aware in (("aware", True), ("global", False)):
                    eng, sched = run(pl, aware, b)
                    record(kind, skew, n_s, b, eng, sched)

    # gate 1: n_shards=1 degradation is exactly the placement-free engine
    drift = max(abs(tps[("aware", "uniform", 1, b)]
                    - tps[("none", "uniform", 0, b)]) for b in batches)
    emit("serving_micro/ep_s1_drift", drift, "must-be-exactly-0")
    # gate 2: shard-aware >= global-union where the placement is skewed
    deep_s, deep_b = max(s for s in shards if s > 1), max(batches)
    gain = (tps[("aware", "zipf", deep_s, deep_b)]
            / tps[("global", "zipf", deep_s, deep_b)]
            if tps.get(("global", "zipf", deep_s, deep_b)) else 0.0)
    emit(f"serving_micro/ep_s{deep_s}_zipf_B{deep_b}_aware_over_global",
         gain, "must-be>=1")
    save_json("serving_micro_ep_sweep",
              {"hw": {"name": hw.name, "hbm_bw": hw.hbm_bw,
                      "peak_flops": hw.peak_flops, "ici_bw": hw.ici_bw},
               "num_experts": e, "max_new": max_new, "rows": rows,
               "s1_drift": drift, "deep_shards": deep_s, "deep_B": deep_b,
               "aware_over_global": gain})
    _gate(drift == 0.0,
          f"shards=1 tokens/s drifted {drift!r} from the placement-free "
          "engine (must be exactly 0)")
    _gate(gain >= 1.0,
          f"shard-aware planning lost to the global-union planner on the "
          f"zipf placement at shards={deep_s}, B={deep_b}: x{gain:.4f}")
    return rows


# --------------------------------------------------------------------- #
# Offload sweep (model clock): tiered expert residency with
# speculation-guided prefetch (docs/offload.md)
# --------------------------------------------------------------------- #

def _offload_hw():
    """The ep-sweep regime plus a host link (regime choice, not a
    physical device): `host_bw` scaled so one expert's host->HBM fetch
    (~786us here) is a real fraction of the reduced model's ~5ms pass —
    small enough that the draft/sample + pre-MoE compute window can hide
    a prefetched fetch, large enough that a demand miss on the critical
    path costs visible tokens/s. On real hardware the same ratio comes
    out of PCIe vs HBM figures (TPU_V5E.host_bw)."""
    from repro.core import Hardware
    return Hardware("tpu-v5e-offload-scaled", hbm_bw=1e9, peak_flops=1e10,
                    ici_bw=5e8, host_bw=1e9)


def _offload_requests(cfg, n_requests: int, max_new: int, n_slices: int = 6):
    """Draftable periodic prompts over narrow vocab *slices* — each
    request's tokens come from one of `n_slices` disjoint vocab bands, so
    its routed expert set is a content-specific subset (measured: mean
    per-pass working set ~2.4 of 8 experts per request at B=1, vs
    near-saturated under full-vocab `_ep_requests`). Consecutive requests
    use different bands, so the working set *rotates* at request boundaries
    and within the mixed batch — the locality-transition regime where a
    prefetcher can act (a fully saturated working set is a provable tie:
    every resident is re-touched every pass, so no eviction is safe and
    every policy pays the same forced fetches)."""
    rng = np.random.default_rng(11)
    v0, v1 = 3, cfg.vocab_size
    out = []
    for i in range(n_requests):
        sl = i % n_slices
        lo = v0 + sl * (v1 - v0) // n_slices
        hi = v0 + (sl + 1) * (v1 - v0) // n_slices
        period = 4 + 2 * (i % 3)
        pat = [int(x) for x in rng.integers(lo, hi, period)]
        out.append(Request(request_id=f"r{i}", prompt=pat * (32 // period),
                           max_new=max_new, task=f"s{sl}"))
    return out


def offload_sweep(fast: bool = False, batches=(2, 4), slots: int = 5):
    """Tiered-residency serving on the deterministic model clock
    (docs/offload.md). The trained 8-expert model (`_ep_model`) runs with
    EVERY expert demoted to the host tier and an HBM cap of `slots`
    cache slots — the vocab-sliced workload's rotating working set
    exceeds the cap, so misses are forced at every locality transition —
    with the engine's router-probe prefetcher on vs off, under chunked
    prefill (chunk=16: admissions enter the step loop, where the
    prefetcher can see them). Two reference runs per batch size: `plain`
    (no residency at all) and `all_hbm` (a ResidencyState tracking an
    all-hbm placement — the pipeline fully threaded but the tier empty).

    Gates (committed artifact + CI smoke):
      * uncapped tier drift: `all_hbm` tokens/s == `plain` EXACTLY, per B
        (the residency layer must be invisible without a host tier);
      * per B: prefetch-on tokens/s > prefetch-off under the
        miss-forcing cap (speculation's lookahead must buy real latency
        hiding, not just move the fetches earlier).
    Hit-rate / fetch-bytes / eviction telemetry lands in the artifact."""
    from repro.core import (BatchSpecPlanner, ExpertPlacement,
                            PlannerConfig, ResidencyState, expert_hbm_bytes)
    cfg, params = _ep_model()
    hw = _offload_hw()
    e = cfg.num_experts
    eb = expert_hbm_bytes(cfg)
    if fast:
        batches = tuple(b for b in batches if b == max(batches))
    n_requests, max_new = (12, 16) if fast else (24, 24)
    pl = ExpertPlacement.contiguous(e, 1)
    host_ids = list(range(e))              # the whole expert population
    tiered = pl.offload(host_ids)
    cap = slots * eb                       # nothing pinned: cap == cache

    def run(b, residency=None, prefetch=True):
        planner = BatchSpecPlanner(
            cfg, hw,
            config=PlannerConfig(policy="joint", stagger_tests=False),
            placement=pl if residency is None else None,
            residency=residency)
        return _run_engine(cfg, params,
                           _offload_requests(cfg, n_requests, max_new),
                           controller=_ep_controller, max_batch=b, hw=hw,
                           chunk=16,
                           placement=None if residency is not None else pl,
                           residency=residency, prefetch=prefetch,
                           planner=planner)

    rows = []
    tps = {}

    def record(mode, b, eng, sched, rs=None):
        tel = eng.telemetry
        row = {"mode": mode, "B": b,
               "tokens_per_s": sched.tokens_per_second(),
               "mean_request_utility": sched.mean_request_utility(),
               "prefetch_hit_rate": tel.prefetch_hit_rate,
               "fetch_bytes": tel.fetch_bytes,
               "evictions": tel.evictions,
               "t_fetch_unhidden": sum(s.t_fetch for s in tel.steps),
               "steps": len(tel.steps)}
        if rs is not None:
            row["residency"] = rs.snapshot()
        rows.append(row)
        tps[(mode, b)] = row["tokens_per_s"]
        emit(f"serving_micro/offload_{mode}_B{b}_tokens_per_s",
             row["tokens_per_s"],
             f"hit={row['prefetch_hit_rate']:.3f};"
             f"fetchMB={row['fetch_bytes'] / 1e6:.2f};"
             f"evict={row['evictions']}")
        return row

    for b in batches:
        eng, sched = run(b)
        record("plain", b, eng, sched)
        eng, sched = run(b, ResidencyState(pl, cfg))
        record("all_hbm", b, eng, sched)
        rs_on = ResidencyState(tiered, cfg, cap_bytes=cap)
        eng, sched = run(b, rs_on)
        record("prefetch_on", b, eng, sched, rs_on)
        rs_off = ResidencyState(tiered, cfg, cap_bytes=cap)
        eng, sched = run(b, rs_off, prefetch=False)
        record("prefetch_off", b, eng, sched, rs_off)

    drift = max(abs(tps[("all_hbm", b)] - tps[("plain", b)])
                for b in batches)
    gains = {b: (tps[("prefetch_on", b)] / tps[("prefetch_off", b)]
                 if tps[("prefetch_off", b)] else 0.0) for b in batches}
    emit("serving_micro/offload_all_hbm_drift", drift,
         "must-be-exactly-0")
    for b in batches:
        emit(f"serving_micro/offload_B{b}_prefetch_on_over_off", gains[b],
             "must-be>1")
    on_rows = [r for r in rows if r["mode"] == "prefetch_on"]
    save_json("serving_micro_offload_sweep",
              {"hw": {"name": hw.name, "hbm_bw": hw.hbm_bw,
                      "peak_flops": hw.peak_flops, "ici_bw": hw.ici_bw,
                      "host_bw": hw.host_bw},
               "num_experts": e, "host_experts": host_ids,
               "expert_bytes": eb, "cap_bytes": cap, "slots": slots,
               "max_new": max_new, "rows": rows,
               "all_hbm_drift": drift,
               "prefetch_on_over_off": {str(b): gains[b]
                                        for b in batches}})
    _gate(drift == 0.0,
          f"all-hbm residency drifted {drift!r} tokens/s from the "
          "residency-free engine (must be exactly 0)")
    for b in batches:
        _gate(gains[b] > 1.0,
              f"prefetch did not pay at B={b} under the miss-forcing cap: "
              f"on {tps[('prefetch_on', b)]:.2f} vs off "
              f"{tps[('prefetch_off', b)]:.2f} tokens/s (x{gains[b]:.4f})")
    _gate(all(r["prefetch_hit_rate"] > 0 and r["fetch_bytes"] > 0
              for r in on_rows),
          "prefetch-on rows show no cache traffic — the cap never forced "
          "a fetch (sweep regime mis-configured)")
    return rows


# --------------------------------------------------------------------- #
# Overlap sweep (model clock): layered streaming vs whole-expert
# residency under the offload sweep's miss-forcing cap
# --------------------------------------------------------------------- #

def overlap_sweep(fast: bool = False, batches=(2, 4), slots: int = 5):
    """Layered-streaming sweep (docs/offload.md, layered streaming): the
    offload sweep's miss-forcing regime — trained 8-expert model
    (`_ep_model`), EVERY expert host-tiered under `slots` HBM cache
    slots, the vocab-sliced rotating working set — re-run at both
    residency granularities with the prefetcher on and off. Layer
    granularity turns the prefetch stage into a layer pipeline: layer
    l's slices hide behind the draft window PLUS the compute of layers
    < l, double-buffered against the previous pass's tail.

    Gates (committed artifact + CI smoke):
      * whole-expert drift: granularity="expert" prefetch-on rows must
        reproduce the committed offload-sweep artifact's tokens/s
        EXACTLY — the layered refactor must leave PR 7's whole-expert
        path bit for bit (full runs only: --fast runs a reduced workload
        the committed artifact doesn't cover);
      * per B: layer-granularity prefetch-on strictly beats whole-expert
        prefetch-on — higher tokens/s AND lower total unhidden fetch
        (the pipeline must hide real latency, not shuffle accounting);
      * per B: the prefetcher still pays within layer granularity
        (on > off — finer units must not break the lookahead's value);
      * analytic float-exactness: `BatchCostOracle.t_batch` ==
        `batch_iteration_time` t_iter under a layer residency and a
        full per-layer hide schedule, exactly, over an allocation grid
        (shared `fetch_time_layered`)."""
    import json
    import os
    from repro.core import (BatchCostOracle, BatchSpecPlanner,
                            ExpertPlacement, PlannerConfig, ResidencyState,
                            batch_iteration_time, expert_hbm_bytes,
                            fetch_hide_schedule)
    from .common import OUT_DIR
    cfg, params = _ep_model()
    hw = _offload_hw()
    e = cfg.num_experts
    eb = expert_hbm_bytes(cfg)
    if fast:
        batches = tuple(b for b in batches if b == max(batches))
    n_requests, max_new = (12, 16) if fast else (24, 24)
    pl = ExpertPlacement.contiguous(e, 1)
    tiered = pl.offload(list(range(e)))    # the whole expert population
    cap = slots * eb

    def run(b, granularity, prefetch=True):
        # the offload sweep's construction, verbatim, plus granularity —
        # the drift gate depends on the expert rows being the SAME run
        rs = ResidencyState(tiered, cfg, cap_bytes=cap,
                            granularity=granularity)
        planner = BatchSpecPlanner(
            cfg, hw,
            config=PlannerConfig(policy="joint", stagger_tests=False),
            residency=rs)
        eng, sched = _run_engine(cfg, params,
                                 _offload_requests(cfg, n_requests,
                                                   max_new),
                                 controller=_ep_controller, max_batch=b,
                                 hw=hw, chunk=16, residency=rs,
                                 prefetch=prefetch, planner=planner)
        return eng, sched, rs

    rows = []
    tps, unhid = {}, {}
    for b in batches:
        for gran in ("expert", "layer"):
            for prefetch in (True, False):
                eng, sched, rs = run(b, gran, prefetch)
                tel = eng.telemetry
                key = (gran, "on" if prefetch else "off", b)
                tps[key] = sched.tokens_per_second()
                unhid[key] = sum(s.t_fetch for s in tel.steps)
                row = {"granularity": gran,
                       "prefetch": prefetch, "B": b,
                       "tokens_per_s": tps[key],
                       "t_fetch_unhidden": unhid[key],
                       "prefetch_hit_rate": tel.prefetch_hit_rate,
                       "fetch_bytes": tel.fetch_bytes,
                       "evictions": tel.evictions,
                       "steps": len(tel.steps),
                       "residency": rs.snapshot()}
                if gran == "layer":
                    lay = [s.t_fetch_by_layer for s in tel.steps
                           if s.t_fetch_by_layer]
                    if lay:
                        row["t_fetch_by_layer_sum"] = [
                            float(sum(col)) for col in zip(*lay)]
                rows.append(row)
                emit(f"serving_micro/overlap_{gran}_"
                     f"{'on' if prefetch else 'off'}_B{b}_tokens_per_s",
                     tps[key],
                     f"hit={row['prefetch_hit_rate']:.3f};"
                     f"unhid={unhid[key]:.5f}")

    # analytic float-exactness of the layered pricing, oracle vs pricer
    rs = ResidencyState(tiered, cfg, cap_bytes=cap, granularity="layer")
    sched_h = fetch_hide_schedule(cfg, 1e-4, 2e-3)
    ctx = [64, 96, 128]
    orc = BatchCostOracle(cfg, hw, ctx, residency=rs, fetch_hide=sched_h)
    exact_drift = 0.0
    for ns in ([1, 1, 1], [4, 0, 2], [0, 0, 0], [3, 5, 7], [9, 1, 4]):
        ref = batch_iteration_time(cfg, hw, ns, ctx, residency=rs,
                                   fetch_hide=sched_h)
        exact_drift = max(exact_drift,
                          abs(orc.t_batch(ns) - ref["t_iter"]),
                          abs(orc.fetch_unhidden(ns)
                              - ref["t_fetch_unhidden"]))
    emit("serving_micro/overlap_layered_pricing_drift", exact_drift,
         "oracle-vs-batch_iteration_time;must-be-exactly-0")

    # whole-expert drift vs the committed offload-sweep artifact
    expert_drift = None
    ref_path = os.path.join(OUT_DIR, "serving_micro_offload_sweep.json")
    if not fast and os.path.exists(ref_path):
        with open(ref_path) as f:
            ref_rows = json.load(f)["rows"]
        ref_tps = {r["B"]: r["tokens_per_s"] for r in ref_rows
                   if r["mode"] == "prefetch_on"}
        expert_drift = max(abs(tps[("expert", "on", b)] - ref_tps[b])
                           for b in batches if b in ref_tps)
        emit("serving_micro/overlap_expert_drift_vs_offload_artifact",
             expert_drift, "must-be-exactly-0")

    gains = {b: (tps[("layer", "on", b)] / tps[("expert", "on", b)])
             for b in batches}
    for b in batches:
        emit(f"serving_micro/overlap_B{b}_layer_over_expert", gains[b],
             f"unhid {unhid[('layer', 'on', b)]:.5f} vs "
             f"{unhid[('expert', 'on', b)]:.5f};must-be>1")
    save_json("serving_micro_overlap_sweep",
              {"hw": {"name": hw.name, "hbm_bw": hw.hbm_bw,
                      "peak_flops": hw.peak_flops, "ici_bw": hw.ici_bw,
                      "host_bw": hw.host_bw},
               "num_experts": e, "expert_bytes": eb,
               "cap_bytes": cap, "slots": slots, "max_new": max_new,
               "rows": rows,
               "layer_over_expert": {str(b): gains[b] for b in batches},
               "layered_pricing_drift": exact_drift,
               "expert_drift_vs_offload_artifact": expert_drift})
    _gate(exact_drift == 0.0,
          f"layered pricing drifted {exact_drift!r} between "
          "BatchCostOracle and batch_iteration_time (must be exactly 0)")
    if expert_drift is not None:
        _gate(expert_drift == 0.0,
              f"granularity='expert' drifted {expert_drift!r} tokens/s "
              "from the committed offload-sweep artifact (must be "
              "exactly 0 — the layered refactor may not move the "
              "whole-expert path)")
    for b in batches:
        _gate(unhid[("layer", "on", b)] < unhid[("expert", "on", b)],
              f"layered streaming did not lower unhidden fetch at B={b}: "
              f"{unhid[('layer', 'on', b)]:.5f} vs "
              f"{unhid[('expert', 'on', b)]:.5f}")
        _gate(gains[b] > 1.0,
              f"layered streaming did not pay at B={b}: "
              f"{tps[('layer', 'on', b)]:.2f} vs "
              f"{tps[('expert', 'on', b)]:.2f} tokens/s (x{gains[b]:.4f})")
        _gate(tps[("layer", "on", b)] > tps[("layer", "off", b)],
              f"prefetch did not pay at layer granularity, B={b}: "
              f"on {tps[('layer', 'on', b)]:.2f} vs off "
              f"{tps[('layer', 'off', b)]:.2f} tokens/s")
    return rows


# --------------------------------------------------------------------- #
# Chunked-prefill sweep (model clock): queue depth x chunk -> TTFT / TPOT
# --------------------------------------------------------------------- #

def _prefill_requests(cfg, n_requests: int, prompt_len: int, max_new: int):
    """Long draftable prompts with staggered output lengths — the
    steady-state admission regime, where retirements interleave with
    admissions and a new request's prefill can ride in-flight decode
    passes."""
    rng = np.random.default_rng(23)
    reqs = []
    for i in range(n_requests):
        period = 6 + 2 * (i % 3)
        pat = [int(x) for x in rng.integers(3, cfg.vocab_size, period)]
        prompt = (pat * (prompt_len // period + 1))[:prompt_len]
        reqs.append(Request(request_id=f"r{i}", prompt=prompt,
                            max_new=max_new + 2 * max_new * (i % 3),
                            task=f"p{period}"))
    return reqs


def prefill_sweep(fast: bool = False, depths=(2, 8), chunks=None):
    """Queue depth x chunk size grid on the deterministic model clock.

    chunk=0 is the legacy blocking admission: every join stalls all
    in-flight decodes for the full prefill, and B queued prompts pay B
    serial weight reads. chunk>0 co-schedules prefill chunks into the
    shared verification pass: concurrent admissions share one weight read
    and ride decode passes that happen anyway. Small chunks trade TTFT for
    decode interference (more steps, each with its fixed overhead — the
    Sarathi-style trade); large chunks amortize it, so under a deep queue
    the best chunked point must come out with LOWER mean TTFT than blocking
    (checked, like the batch-sweep drift gate)."""
    cfg = get_config("mixtral-8x7b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompt_len = 96 if fast else 192
    max_new = 8 if fast else 12
    if chunks is None:
        chunks = (0, prompt_len // 3, 2 * prompt_len // 3, prompt_len)

    rows = []
    for depth in depths:
        for chunk in chunks:
            eng, sched = _run_engine(
                cfg, params,
                _prefill_requests(cfg, depth, prompt_len, max_new),
                max_batch=4, chunk=chunk)
            tel = eng.telemetry
            row = {
                "depth": depth,
                "chunk": chunk,
                "mean_ttft": sched.mean_ttft(),
                "mean_queue_delay": sched.mean_queue_delay(),
                "mean_tpot": sched.mean_tpot(),
                "tokens_per_s": sched.tokens_per_second(),
                "prefill_token_frac": tel.prefill_token_frac,
                "steps": len(tel.steps),
            }
            rows.append(row)
            emit(f"serving_micro/prefill_d{depth}_c{chunk}_mean_ttft",
                 row["mean_ttft"],
                 f"queue={row['mean_queue_delay']:.4f}s")
            emit(f"serving_micro/prefill_d{depth}_c{chunk}_tokens_per_s",
                 row["tokens_per_s"],
                 f"prefill_frac={row['prefill_token_frac']:.3f}")

    deep = max(depths)
    blocking = [r for r in rows if r["depth"] == deep and r["chunk"] == 0]
    chunked = [r for r in rows if r["depth"] == deep and r["chunk"] > 0]
    if not blocking or not chunked:
        raise ValueError("prefill sweep needs chunk=0 and a chunked point "
                         "at the deepest queue for the admission gate")
    best = min(chunked, key=lambda r: r["mean_ttft"])
    gain = blocking[0]["mean_ttft"] / best["mean_ttft"] \
        if best["mean_ttft"] else 0.0
    emit("serving_micro/prefill_deep_queue_ttft_gain", gain,
         f"blocking/chunk{best['chunk']};must-be>1")
    save_json("serving_micro_prefill_sweep",
              {"prompt_len": prompt_len, "max_new": max_new,
               "max_batch": 4, "rows": rows,
               "deep_queue_ttft_gain": gain,
               "best_chunk": best["chunk"]})
    _gate(gain > 1.0,
          f"chunked admission did not beat blocking TTFT at depth {deep} "
          f"(gain {gain:.3f})")
    return rows


# --------------------------------------------------------------------- #
# Kernel/calibration sweep (--calibrate): packed-vs-dense traffic by union
# occupancy, wall-clock calibration of the analytic cost model, and the
# packed-path bit-identity gate
# --------------------------------------------------------------------- #

def _occupancy_cfg():
    """The reduced Mixtral widened to E=16 experts so the union-occupancy
    axis has room below the 0.25 gate point (the stock reduced config's
    E=4 saturates at two tokens)."""
    import dataclasses
    return dataclasses.replace(get_config("mixtral-8x7b").reduced(),
                               num_experts=16)


def _occupancy_sweep(fast: bool = False):
    """Dense vs packed expert traffic and wall time by union occupancy.

    For token counts T in {1..E/k..}, reports the packed path's bucketed
    union cap U_pad, both paths' per-layer expert-weight bytes and FFN
    FLOPs (`moe.moe_pass_counters` — dry-run counters that mirror what the
    dispatch paths execute), and measured wall microseconds per apply.
    Gates: at U/E <= 0.25 packed moves <= 0.35x the dense expert bytes;
    packed traffic grows monotonically in U; at U = E packed and dense
    counters agree exactly."""
    from repro.models import moe
    cfg = _occupancy_cfg()
    params = moe.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    reps = 5 if fast else 20
    rows = []
    for t in (1, 2, 4, 8, 16):
        cd = moe.moe_pass_counters(cfg, t, capacity_policy="exact",
                                   packed=False)
        cp = moe.moe_pass_counters(cfg, t, capacity_policy="exact",
                                   packed=True)
        x = jax.random.normal(jax.random.PRNGKey(t), (t, cfg.d_model),
                              jnp.float32)

        def _us(packed):
            fn = jax.jit(lambda p, xx: moe.apply_moe(
                cfg, p, xx, capacity_policy="exact", packed=packed)[0])
            jax.block_until_ready(fn(params, x))   # compile
            samples = []
            for _ in range(reps):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(params, x))
                samples.append(time.perf_counter() - t0)
            return float(np.median(samples) * 1e6)

        row = {
            "tokens": t,
            "u_cap": cp["experts_streamed"],
            "occupancy": cp["experts_streamed"] / cfg.num_experts,
            "dense_expert_bytes": cd["expert_weight_bytes"],
            "packed_expert_bytes": cp["expert_weight_bytes"],
            "bytes_ratio": (cp["expert_weight_bytes"]
                            / cd["expert_weight_bytes"]),
            "dense_ffn_flops": cd["ffn_flops"],
            "packed_ffn_flops": cp["ffn_flops"],
            "dense_us": _us(False),
            "packed_us": _us(True),
        }
        rows.append(row)
        emit(f"serving_micro/kernel_T{t}_packed_bytes_ratio",
             row["bytes_ratio"],
             f"U={row['u_cap']}/{cfg.num_experts};"
             f"packed={row['packed_us']:.0f}us;dense={row['dense_us']:.0f}us")

    for r in rows:
        _gate(not (r["occupancy"] <= 0.25 and r["bytes_ratio"] > 0.35),
              f"packed path moved {r['bytes_ratio']:.2f}x the dense "
              f"expert bytes at occupancy {r['occupancy']:.2f} "
              "(gate: <= 0.35x at U/E <= 0.25)")
    traffic = [r["packed_expert_bytes"] for r in rows]
    _gate(not any(b2 < b1 for b1, b2 in zip(traffic, traffic[1:])),
          f"packed expert traffic not monotone in U: {traffic}")
    full = [r for r in rows if r["u_cap"] == cfg.num_experts]
    _gate(bool(full), "occupancy sweep never reached U = E")
    for r in full:
        _gate(r["packed_expert_bytes"] == r["dense_expert_bytes"]
              and r["packed_ffn_flops"] == r["dense_ffn_flops"],
              f"packed != dense counters at U = E (T={r['tokens']}): "
              f"{r['packed_expert_bytes']} vs {r['dense_expert_bytes']} "
              f"bytes, {r['packed_ffn_flops']} vs "
              f"{r['dense_ffn_flops']} FLOPs")
    return {"num_experts": cfg.num_experts,
            "experts_per_token": cfg.experts_per_token, "rows": rows}


def _packed_stream_check(fast: bool = False):
    """B=1 and B=4 packed-vs-dense emitted token streams must be
    bit-identical: the packed path performs the same contractions in the
    same dtype, so no numerics drift can reach rejection sampling."""
    cfg = get_config("mixtral-8x7b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    max_new = 12 if fast else 24

    def streams(b, packed):
        _, sched = _run_engine(cfg, params,
                               _sweep_requests(cfg, max(b, 4), max_new),
                               max_batch=b, packed=packed)
        return {r.telemetry.request_id: r.tokens for r in sched.results}

    for b in (1, 4):
        dense, packed = streams(b, False), streams(b, True)
        diff = [k for k in dense if dense[k] != packed.get(k)]
        _gate(dense == packed,
              f"packed token streams diverged from dense at B={b} "
              f"(requests {diff}) — numerics drift reached sampling")
        emit(f"serving_micro/packed_B{b}_bit_identical", 1.0,
             "must-be-1")
    return True


# --------------------------------------------------------------------- #
# Quantized expert path sweep (docs/quantization.md)
# --------------------------------------------------------------------- #

def _measured_union_probe(cfg, params):
    """Memoized n -> measured mean-per-layer unique experts: run the REAL
    router (a fresh-cache prefill over a draftable periodic prompt of
    length n) and read the union the pass actually routed — the measured
    counterpart of `expected_unique_experts`, and what distinguishes the
    measured crossover from the predicted one."""
    rng = np.random.default_rng(11)
    pat = [int(x) for x in rng.integers(3, cfg.vocab_size, 8)]
    memo = {}

    def union(n):
        if n not in memo:
            toks = jnp.asarray([(pat * (n // 8 + 1))[:n]], jnp.int32)
            cache = T.init_cache(cfg, 1, max(n, 8))
            _, _, aux = T.prefill(cfg, params, toks, cache)
            memo[n] = float(np.asarray(aux["unique_experts"],
                                       np.float64).mean())
        return memo[n]

    return union


def _fine_crossover(cfg, hw, precision=None, union=None,
                    max_chunk: int = 512) -> int:
    """`cm.prefill_crossover_tokens` at integer (not pow-2) resolution:
    bracket by doubling, then bisect `prefill_time`'s compute_bound flag.
    `union` (from `_measured_union_probe`) substitutes measured expert
    unions for the analytic model at every probe point — the doubling
    bracket keeps probes near the crossover so the measured variant never
    prefills far beyond it."""
    def bound(n):
        u = union(n) if union else None
        return cm.prefill_time(cfg, hw, n, unique_experts=u,
                               precision=precision)["compute_bound"]

    if bound(1):
        return 1
    lo = hi = 1
    while hi < max_chunk and not bound(hi * 2):
        hi *= 2
        lo = hi
    hi *= 2
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if bound(mid):
            hi = mid
        else:
            lo = mid
    return hi


def quant_sweep(fast: bool = False):
    """Quantized expert paths end to end (docs/quantization.md): the
    trained reduced Mixtral served bf16, int8 (true quantized storage,
    dequant on the packed path), and fp8 (fake-quant numerics, same
    1 byte/param pricing), all under the `_ep_hw` memory-bound regime
    where expert bytes dominate the pass.

    Gates (committed artifact + CI smoke):
      * OFF == DEFAULT, bit for bit: `precision=None` and
        `cm.Precision()` runs emit identical token streams and per-step
        telemetry, with zero `expert_bytes_saved` — quantization off is
        the pre-quantization engine exactly;
      * int8 tokens/s >= bf16 tokens/s at equal acceptance (within 2pp;
        the trained copy task's greedy argmax survives absmax int8, so
        the comparison is bytes vs bytes, not acceptance vs acceptance);
      * the predicted bf16->int8 roofline-crossover shift
        (`_fine_crossover` under analytic unions) matches the shift
        re-measured with the real router's unions, within the planner's
        measured `plan_time_error` band (floored at 0.25 — crossovers
        are integer-quantized)."""
    from repro.models.moe import quantize_transformer_experts
    cfg, params = _ep_model()
    hw = _ep_hw()
    qp = quantize_transformer_experts(params, "int8")
    fp = quantize_transformer_experts(params, "fp8")
    b = 4 if fast else 8
    max_new = 12 if fast else 24
    n_requests = 2 * b

    def run(p, prec):
        return _run_engine(cfg, p, _ep_requests(cfg, n_requests, max_new),
                           controller=_ep_controller, max_batch=b, hw=hw,
                           packed=True, precision=prec)

    def accept_rate(sched):
        its = [it for r in sched.results for it in r.telemetry.iterations]
        drafted = sum(it.k_drafted for it in its)
        return (sum(it.tokens_emitted - 1 for it in its) / drafted
                if drafted else 0.0)

    def row(tag, eng, sched):
        return {
            "precision": tag,
            "tokens_per_s": sched.tokens_per_second(),
            "accept_rate": accept_rate(sched),
            "expert_bytes_saved": eng.telemetry.expert_bytes_saved,
            "plan_time_error": sched.planner_stats()["plan_time_error"],
        }

    # -- gate: quantization off == explicit default, bit for bit -------- #
    eng0, sched0 = run(params, None)
    eng1, sched1 = run(params, cm.Precision())
    streams0 = {r.telemetry.request_id: r.tokens for r in sched0.results}
    streams1 = {r.telemetry.request_id: r.tokens for r in sched1.results}
    tel0 = [(s.t_step, s.t_step_predicted, s.union_experts,
             s.k_granted, s.expert_bytes_saved) for s in
            eng0.telemetry.steps]
    tel1 = [(s.t_step, s.t_step_predicted, s.union_experts,
             s.k_granted, s.expert_bytes_saved) for s in
            eng1.telemetry.steps]
    _gate(streams0 == streams1,
          "precision=None vs Precision() token streams diverged — "
          "quantization-off is not the pre-quantization engine")
    _gate(tel0 == tel1,
          "precision=None vs Precision() per-step telemetry diverged")
    _gate(eng0.telemetry.expert_bytes_saved == 0.0,
          "unquantized run reported nonzero expert_bytes_saved")
    emit("serving_micro/quant_off_bit_identical", 1.0, "must-be-1")

    # -- gate: int8 tokens/s >= bf16 at equal acceptance ---------------- #
    rows = [row("bf16", eng0, sched0)]
    eng_i8, sched_i8 = run(qp, cm.Precision.int8_experts())
    eng_f8, sched_f8 = run(fp, cm.Precision.fp8_experts())
    rows.append(row("int8-experts", eng_i8, sched_i8))
    rows.append(row("fp8-experts", eng_f8, sched_f8))
    bf, i8 = rows[0], rows[1]
    for r in rows:
        emit(f"serving_micro/quant_{r['precision']}_tokens_per_s",
             r["tokens_per_s"],
             f"acc={r['accept_rate']:.3f};"
             f"saved={r['expert_bytes_saved']:.2e}")
    d_acc = abs(i8["accept_rate"] - bf["accept_rate"])
    _gate(d_acc <= 0.02,
          f"int8 acceptance drifted {d_acc:.3f} from bf16 — the "
          "throughput comparison would be confounded (quantization "
          "numerics reached rejection sampling)")
    _gate(i8["tokens_per_s"] >= bf["tokens_per_s"],
          f"int8 tokens/s {i8['tokens_per_s']:.1f} lost to bf16 "
          f"{bf['tokens_per_s']:.1f} at equal acceptance")

    # -- gate: predicted crossover shift matches measured --------------- #
    max_chunk = 256 if fast else 512
    i8_prec = cm.Precision.int8_experts()
    xo = {
        "predicted_bf16": _fine_crossover(cfg, hw, max_chunk=max_chunk),
        "predicted_int8": _fine_crossover(cfg, hw, i8_prec,
                                          max_chunk=max_chunk),
        "measured_bf16": _fine_crossover(
            cfg, hw, union=_measured_union_probe(cfg, params),
            max_chunk=max_chunk),
        "measured_int8": _fine_crossover(
            cfg, hw, i8_prec, union=_measured_union_probe(cfg, qp),
            max_chunk=max_chunk),
    }
    pred_shift = xo["predicted_bf16"] / xo["predicted_int8"]
    meas_shift = xo["measured_bf16"] / xo["measured_int8"]
    band = max(2 * max(bf["plan_time_error"], i8["plan_time_error"]),
               0.25)
    shift_err = abs(pred_shift - meas_shift) / meas_shift
    emit("serving_micro/quant_crossover_shift_predicted", pred_shift,
         f"{xo['predicted_bf16']}->{xo['predicted_int8']}tok")
    emit("serving_micro/quant_crossover_shift_measured", meas_shift,
         f"{xo['measured_bf16']}->{xo['measured_int8']}tok")
    _gate(pred_shift > 1.0 and meas_shift > 1.0,
          f"int8 did not move the crossover left (predicted "
          f"{pred_shift:.3f}x, measured {meas_shift:.3f}x)")
    _gate(shift_err <= band,
          f"predicted crossover shift {pred_shift:.3f}x off measured "
          f"{meas_shift:.3f}x by {shift_err:.2%} (band {band:.2%})")

    out = {"B": b, "max_new": max_new, "hw": hw.name, "rows": rows,
           "crossover": xo, "pred_shift": pred_shift,
           "meas_shift": meas_shift, "shift_err": shift_err,
           "band": band, "off_bit_identical": True}
    save_json("serving_micro_quant_sweep", out)
    return out


def _calibrate_planner(fast: bool = False):
    """Fit `cost_model.Calibration` on the planner-sweep regime and verify
    it: run the joint planner uncalibrated at B=8, fit scale/offset on the
    per-step (predicted, measured) pairs, rerun with the calibrated
    planner (util_floor widened by the post-fit residual,
    `Calibration.adapted_util_floor`), and gate on mean `plan_time_error`
    improving."""
    from repro.core import cost_model as cm
    from repro.core.planner import BatchSpecPlanner, PlannerConfig
    cfg = get_config("mixtral-8x7b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    hw = _planner_hw()
    b = 8
    max_new = 16 if fast else 32

    def run(planner=None):
        return _run_engine(cfg, params, _sweep_requests(cfg, b, max_new),
                           max_batch=b, hw=hw,
                           policy=None if planner else "joint",
                           planner=planner)

    eng0, sched0 = run()
    steps = [s for s in eng0.telemetry.steps
             if s.t_step > 0 and s.t_step_predicted]
    err_before = sched0.planner_stats()["plan_time_error"]
    cal = cm.Calibration.fit([s.t_step_predicted for s in steps],
                             [s.t_step for s in steps],
                             [s.t_a2a for s in steps])

    planner = BatchSpecPlanner(
        cfg, hw,
        config=PlannerConfig(policy="joint",
                             util_floor=cal.adapted_util_floor(1.0)),
        calibration=cal)
    eng1, sched1 = run(planner)
    err_after = sched1.planner_stats()["plan_time_error"]

    emit("serving_micro/calibrate_plan_time_error_before", err_before,
         f"scale={cal.time_scale:.4f};offset={cal.time_offset:.2e}")
    emit("serving_micro/calibrate_plan_time_error_after", err_after,
         "must-be<before")
    _gate(err_before > 0,
          "uncalibrated run reported zero plan_time_error — "
          "nothing to calibrate (regime mis-configured?)")
    _gate(err_after < err_before,
          f"calibration did not improve plan_time_error: "
          f"{err_after:.4f} after vs {err_before:.4f} before")
    return {
        "B": b, "max_new": max_new, "steps_fitted": len(steps),
        "time_scale": cal.time_scale, "time_offset": cal.time_offset,
        "a2a_scale": cal.a2a_scale,
        "resid_before_fit": cal.resid_before,
        "resid_after_fit": cal.resid_after,
        "plan_time_error_before": err_before,
        "plan_time_error_after": err_after,
        "adapted_util_floor": cal.adapted_util_floor(1.0),
    }


def calibrate(fast: bool = False):
    """--calibrate: the three kernel/calibration gates plus the committed
    artifact (experiments/bench/serving_micro_kernel_sweep.json)."""
    occupancy = _occupancy_sweep(fast)
    _packed_stream_check(fast)
    calibration = _calibrate_planner(fast)
    save_json("serving_micro_kernel_sweep",
              {"occupancy": occupancy, "calibration": calibration,
               "packed_bit_identical": True})
    return {"occupancy": occupancy, "calibration": calibration}


# --------------------------------------------------------------------- #
# Sweep table: one row per entrypoint — flag, runner, help. Registration
# and dispatch read this table; adding a sweep is adding a row.
# --------------------------------------------------------------------- #

SWEEPS = (
    ("batch-sweep", batch_sweep,
     "continuous-batching sweep over B in {1,2,4,8}"),
    ("planner-sweep", planner_sweep,
     "joint vs independent K allocation sweep"),
    ("slo-sweep", slo_sweep,
     "mixed-tier TPOT bounds: victim protection vs unconstrained joint "
     "planning"),
    ("ep-sweep", ep_sweep,
     "EP shards x placement skew x B: shard-aware vs global-union "
     "planning"),
    ("offload-sweep", offload_sweep,
     "tiered expert residency: all-hbm drift gate and prefetch-on vs "
     "prefetch-off under a miss-forcing HBM cap"),
    ("overlap-sweep", overlap_sweep,
     "layered streaming: layer vs whole-expert residency granularity "
     "under the miss-forcing cap; whole-expert drift gate and layered "
     "pricing float-exactness"),
    ("prefill-sweep", prefill_sweep,
     "queue depth x chunk size -> TTFT/TPOT sweep"),
    ("quant-sweep", quant_sweep,
     "bf16 vs int8/fp8 expert paths: off==default bit-identity, int8 "
     "tokens/s >= bf16 at equal acceptance, predicted vs measured "
     "roofline-crossover shift"),
    ("calibrate", calibrate,
     "packed-vs-dense traffic by union occupancy, packed bit-identity, "
     "and wall-clock calibration of the analytic cost model"),
)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--no-micro", action="store_true",
                    help="skip the single-call microbenchmarks")
    for flag, _, help_text in SWEEPS:
        ap.add_argument(f"--{flag}", action="store_true", help=help_text)
    args = ap.parse_args()
    if not args.no_micro:
        main(fast=args.fast)
    for flag, fn, _ in SWEEPS:
        if getattr(args, flag.replace("-", "_")):
            fn(fast=args.fast)
