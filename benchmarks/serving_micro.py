"""Real-engine microbenchmarks on CPU with a reduced MoE: wall-clock per
call for the serving primitives (decode step, n-gram drafter, rejection
sampler, Cascade manager). These verify the paper's claim that the
manager/telemetry overhead is negligible relative to an MoE iteration."""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import CascadeController
from repro.core.utility import IterationRecord
from repro.models import transformer as T
from repro.serving import NGramDrafter
from repro.serving.sampler import rejection_sample

from .common import emit


def _bench(fn, n=50, warmup=3):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6  # us


def main(fast: bool = False):
    cfg = get_config("mixtral-8x7b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    cache = T.init_cache(cfg, 1, 512)
    toks = jnp.asarray(np.arange(64)[None, :] % cfg.vocab_size, jnp.int32)
    _, cache, _ = jax.jit(lambda p, t, c: T.prefill(cfg, p, t, c))(
        params, toks, cache)
    step = jax.jit(lambda p, c, t: T.decode_step(cfg, p, c, t))
    tok4 = toks[:, :4]

    us = _bench(lambda: jax.block_until_ready(step(params, cache, tok4)[0]))
    emit("serving_micro/decode_step_T4_reduced_moe", us, "jit;cpu")

    drafter = NGramDrafter()
    hist = list(np.random.default_rng(0).integers(0, 64, 512))
    us = _bench(lambda: drafter.propose(hist, 4), n=200)
    emit("serving_micro/ngram_propose", us, "py;hist=512")

    rng = np.random.default_rng(0)
    p = rng.dirichlet(np.ones(256), size=5).astype(np.float64)
    us = _bench(lambda: rejection_sample(rng, p, [1, 2, 3, 4]), n=500)
    emit("serving_micro/rejection_sample_K4", us, "py;V=256")

    ctl = CascadeController()
    rec = IterationRecord(k=3, tokens=2, t_iter=1e-3)

    def tick():
        ctl.next_k()
        ctl.manager.observe(rec)
    us = _bench(tick, n=2000)
    emit("serving_micro/cascade_manager_tick", us,
         "py;paper-claims-negligible")


if __name__ == "__main__":
    main()
