"""Paper-scale mixed-workload study on the simulator: Fig. 5/13-style
comparison for any MoE config in the registry — including the assigned-pool
giants (kimi-k2-1t-a32b, deepseek-v2-236b) the paper never measured.

    PYTHONPATH=src python examples/mixed_workload.py \
        --arch kimi-k2-1t-a32b --mix all-3
"""

import argparse

from repro.configs import ALL_ARCHS, get_config
from repro.data.workloads import MIXES
from repro.sim.simulator import run_point


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="kimi-k2-1t-a32b", choices=ALL_ARCHS)
    ap.add_argument("--mix", default="all-3", choices=list(MIXES))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--iters", type=int, default=256)
    ap.add_argument("--drafter", default="ngram", choices=["ngram", "eagle"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not cfg.is_moe:
        print(f"note: {args.arch} is dense — verification is ~flat-cost, "
              "so speculation behaves like the paper's dense control")
    mix = list(MIXES[args.mix])
    print(f"{args.arch}  mix={args.mix}  drafter={args.drafter}  "
          f"(virtual TPU-v5e, single-batch)\n")
    print(f"{'policy':12s} {'TPOT speedup':>12s} {'ETR':>6s}")
    for pol in [0, 1, 2, 3, None]:
        r = run_point(cfg, mix, pol, drafter=args.drafter,
                      n_requests=args.requests, iters=args.iters, seed=5)
        name = "cascade" if pol is None else f"static-K{pol}"
        print(f"{name:12s} {r['speedup']:12.3f} {r['etr']:6.2f}")


if __name__ == "__main__":
    main()
