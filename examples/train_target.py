"""Train a ~100M-parameter MoE on the synthetic task mix for a few hundred
steps (deliverable b's training example). Uses scan-over-layers + remat —
the same train_step the multi-pod dry-run lowers at kimi-k2 scale.

    PYTHONPATH=src python examples/train_target.py \
        [--steps 300] [--d-model 512] [--layers 8] [--experts 8]
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save
from repro.configs import get_config
from repro.data import batch_iterator
from repro.training import make_train_step
from repro.training.optimizer import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--experts", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--out", default="experiments/target_100m.msgpack")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("mixtral-8x7b"),
        name="mixtral-100m",
        num_layers=args.layers, d_model=args.d_model,
        num_heads=8, num_kv_heads=4, head_dim=args.d_model // 8,
        d_ff=args.d_model * 4, moe_d_ff=args.d_model * 4,
        num_experts=args.experts, experts_per_token=2,
        vocab_size=4096, dtype="float32")
    n = cfg.param_count()
    print(f"params: {n/1e6:.1f}M total, {cfg.active_param_count()/1e6:.1f}M "
          f"active/token")

    init_state, step = make_train_step(cfg, optimizer=adamw(1e-3))
    state = init_state(jax.random.PRNGKey(0))
    step = jax.jit(step, donate_argnums=0)
    it = batch_iterator("all-3", args.batch, args.seq, vocab=cfg.vocab_size)

    t0 = time.time()
    for i in range(args.steps):
        b = next(it)
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  lb "
                  f"{float(m['lb']):.3f}  gnorm {float(m['grad_norm']):.2f}"
                  f"  ({(time.time()-t0)/(i+1):.2f}s/step)")
    save(args.out, state[0])
    print(f"saved {args.out}")


if __name__ == "__main__":
    main()
