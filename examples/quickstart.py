"""Quickstart: build a reduced MoE, serve one request with Cascade
utility-driven speculation, and print the iteration-level telemetry.

    PYTHONPATH=src python examples/quickstart.py [--arch mixtral-8x7b]
"""

import argparse

import jax

from repro.configs import get_config
from repro.core import CascadeController, StaticKController
from repro.models import transformer as T
from repro.serving import NGramDrafter, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--max-new", type=int, default=48)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"arch={cfg.name}  layers={cfg.num_layers} d_model={cfg.d_model}"
          f"  experts={cfg.num_experts or '-'}")
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    engine = ServingEngine(cfg, params, NGramDrafter(), max_len=512,
                           temperature=0.0, clock="model")
    prompt = [5, 6, 7, 8, 9] * 8  # n-gram-friendly prompt

    base = engine.generate(prompt, max_new=args.max_new,
                           controller=StaticKController(0))
    res = engine.generate(prompt, max_new=args.max_new,
                          controller=CascadeController())
    assert res.tokens == base.tokens, "speculation must be lossless"

    tel = res.telemetry
    print(f"\noutput tokens: {tel.output_tokens}   iterations:"
          f" {len(tel.iterations)}   ETR: {tel.etr:.2f}")
    print(f"TPOT: cascade {tel.tpot*1e3:.3f} ms/token  vs  no-spec "
          f"{base.telemetry.tpot*1e3:.3f} ms/token  (virtual TPU-v5e clock)")
    print("\niter  K  emitted  unique_experts  utility  phase")
    for it in tel.iterations[:20]:
        print(f"{it.iteration:4d} {it.k_requested:2d} {it.tokens_emitted:7d}"
              f" {it.unique_experts:14.1f}  {it.utility:7.2f}  {it.phase}")


if __name__ == "__main__":
    main()
