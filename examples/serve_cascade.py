"""End-to-end driver (deliverable b): train a small MoE on the synthetic
task mix, checkpoint it, then serve a batched mixed-request stream
(code+math+extract) comparing no-spec / static-K / Cascade — the paper's
Fig. 13 experiment, for real, at laptop scale.

    PYTHONPATH=src python examples/serve_cascade.py \
        [--steps 200] [--requests 6] [--max-new 48]
"""

import argparse
import dataclasses
import os

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import restore, save
from repro.configs import get_config
from repro.core import CascadeController, StaticKController
from repro.data import batch_iterator, make_sample
from repro.serving import NGramDrafter, Request, Scheduler, ServingEngine
from repro.training import make_train_step
from repro.training.optimizer import adamw

CKPT = "experiments/serve_cascade_target.msgpack"


def train_target(cfg, steps: int):
    if os.path.exists(CKPT):
        print(f"restoring target from {CKPT}")
        return restore(CKPT)
    init_state, step = make_train_step(cfg, optimizer=adamw(2e-3))
    state = init_state(jax.random.PRNGKey(0))
    step = jax.jit(step)
    it = batch_iterator("all-3", 16, 96, vocab=cfg.vocab_size, seed=0,
                        prompt_len=48)
    for i in range(steps):
        b = next(it)
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        if i % 25 == 0 or i == steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):.3f}  "
                  f"lb {float(m['lb']):.3f}")
    save(CKPT, state[0])
    return state[0]


def serve(cfg, params, n_requests: int, max_new: int):
    rng = np.random.default_rng(1)
    tasks = ["code", "math", "extract"]
    reqs = []
    for i in range(n_requests):
        s = make_sample(tasks[i % 3], rng, vocab=cfg.vocab_size,
                        prompt_len=48, cont_len=1)
        reqs.append(Request(request_id=f"r{i}", prompt=s.prompt,
                            max_new=max_new, task=s.task))

    results = {}
    for name, factory in [
            ("no-spec", lambda: StaticKController(0)),
            ("static-K3", lambda: StaticKController(3)),
            ("cascade", lambda: CascadeController())]:
        eng = ServingEngine(cfg, params, NGramDrafter(), max_len=512,
                            temperature=0.0, clock="model")
        sched = Scheduler(eng, controller_factory=factory)
        sched.run(list(reqs))
        tps = sched.tokens_per_second()
        etr = (sum(r.telemetry.output_tokens for r in sched.results)
               / sum(len(r.telemetry.iterations) for r in sched.results))
        results[name] = (tps, etr, sched.results)
        print(f"{name:10s}  {tps:9.1f} tok/s (virtual v5e)  ETR={etr:.2f}")

    base_tokens = [r.tokens for r in results["no-spec"][2]]
    for name in ("static-K3", "cascade"):
        assert [r.tokens for r in results[name][2]] == base_tokens, \
            f"{name} changed outputs!"
    print("\nlossless: all policies emitted identical greedy outputs")
    print(f"cascade speedup vs no-spec: "
          f"{results['cascade'][0]/results['no-spec'][0]:.3f}x; "
          f"static-K3: {results['static-K3'][0]/results['no-spec'][0]:.3f}x")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=48)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config("mixtral-8x7b").reduced(),
                              vocab_size=128, num_layers=2)
    os.makedirs("experiments", exist_ok=True)
    params = train_target(cfg, args.steps)
    serve(cfg, params, args.requests, args.max_new)


if __name__ == "__main__":
    main()
